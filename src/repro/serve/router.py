"""Scheme router: one batch of indices in, per-server work out.

The router is the seam between the scheduler (which hands over a padded
[B] index batch) and the execution backend (which answers per-server
payloads). It owns exactly the scheme-shaped decisions:

  * which replicas to contact (all d, or the straggler-policy's fastest t
    for Subset-PIR),
  * what each contacted server receives (query *masks* for the XOR
    family chor/sparse/as-sparse/subset, plain *index requests* for
    direct/as-direct),
  * how the per-server responses reconstruct into records (XOR for the
    mask family, response selection for direct).

Query generation reuses the exact per-scheme functions the reference
``Scheme.retrieve`` path uses, so for a given key the routed batch and the
single-host reference produce identical wire bits — that is what makes the
sharded-equals-single-host proofs (tests/_multidevice_checks.py) exact
rather than statistical.

For the cross-batch cache (DESIGN.md §Cross-batch cache) the router also
splits planning in two: :meth:`SchemeRouter.precompute` generates the
query-independent randomness of a whole batch ahead of time, and
``plan(..., pre=...)`` finishes it for the actual indices. Because the
underlying scheme functions are themselves ``assemble ∘ precompute``,
``plan(key, n, q)`` and ``plan(key, n, q, pre=precompute(key, n, B))``
produce bit-identical payloads (asserted in tests/test_serve_cache.py) —
prefetching moves work off the flush path without changing a single wire
bit or the adversary's view.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import chor, direct, sparse, subset
from repro.core.schemes import SCHEMES, Scheme

__all__ = ["RoutedBatch", "SubsetPre", "SchemeRouter"]

# schemes whose servers XOR-fold masked records ("mask" kind) vs. answer
# plain index requests ("index" kind)
MASK_SCHEMES = ("chor", "sparse", "as-sparse", "subset")
INDEX_SCHEMES = ("direct", "as-direct")


@dataclasses.dataclass
class RoutedBatch:
    """One batch's per-server execution plan.

    kind "mask" : payload [d_eff, B, n] {0,1} uint8 request masks
    kind "index": payload [d_eff, B, p/d] int32 record indices
    ``servers`` are the replica ids contacted (len d_eff ≤ scheme.d);
    ``theta`` is set for the sparse family so the backend can pick the
    gather path.
    """

    kind: str
    payload: jnp.ndarray
    servers: Tuple[int, ...]
    q_idx: jnp.ndarray
    theta: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SubsetPre:
    """Precomputed Subset-PIR plan half: the replica-choice key plus the
    Chor randomness for the t contacted servers."""

    k_srv: jax.Array
    chor_pre: chor.ChorPre


class SchemeRouter:
    """Dispatches chor/sparse/direct/subset/as-* batches.

    ``pick_servers(t) -> Sequence[int]`` supplies Subset-PIR's replica
    choice — the serving pipeline passes its straggler policy (fastest-t by
    latency EMA); the default is the paper's uniform random subset.
    """

    def __init__(
        self,
        scheme: Scheme,
        *,
        pick_servers: Optional[Callable[[int], Sequence[int]]] = None,
    ):
        if scheme.name not in SCHEMES:
            raise ValueError(
                f"unknown scheme {scheme.name!r}; choose from {SCHEMES}"
            )
        self.scheme = scheme
        self._pick_servers = pick_servers

    # ------------------------------------------------------------ planning
    def precompute(self, key: jax.Array, n: int, b: int) -> Optional[Any]:
        """Pre-generate the query-independent randomness of a [b]-batch.

        Returns a scheme-specific opaque object for ``plan(..., pre=...)``,
        or None where planning has no query-independent half (the direct
        family's dummy draws depend on the queried index). The result is
        **single-use**: feed it to exactly one plan() call.
        """
        sch = self.scheme
        if sch.name == "chor":
            return chor.precompute_queries(key, n, sch.d, b)
        if sch.name in ("sparse", "as-sparse"):
            return sparse.precompute_query_randomness(
                key, n, sch.d, sch.theta, b
            )
        if sch.name == "subset":
            k_srv, k_q = jax.random.split(key)
            return SubsetPre(
                k_srv=k_srv, chor_pre=chor.precompute_queries(k_q, n, sch.t, b)
            )
        return None

    def plan(
        self,
        key: jax.Array,
        n: int,
        q_idx: jnp.ndarray,
        *,
        pre: Optional[Any] = None,
    ) -> RoutedBatch:
        """[B] indices -> per-server payloads for one batch.

        ``pre`` (from :meth:`precompute`) supplies pre-generated batch
        randomness; ``plan(key, n, q)`` ≡ ``plan(key, n, q,
        pre=precompute(key, n, B))`` bit-for-bit.
        """
        sch = self.scheme
        name = sch.name
        if pre is not None:
            pre_n = pre.chor_pre.n if name == "subset" else getattr(pre, "n", n)
            if pre_n != n:
                raise ValueError(f"pre built for n={pre_n}, store has n={n}")

        if name == "chor":
            packed = (
                chor.assemble_queries(pre, q_idx) if pre is not None
                else chor.gen_queries(key, n, sch.d, q_idx)
            )
            return RoutedBatch(
                "mask", chor.query_masks(packed, n), tuple(range(sch.d)), q_idx
            )

        if name in ("sparse", "as-sparse"):
            masks = (
                sparse.assemble_query_matrix(pre, q_idx) if pre is not None
                else sparse.gen_query_matrix(key, n, sch.d, sch.theta, q_idx)
            )
            return RoutedBatch(
                "mask", masks, tuple(range(sch.d)), q_idx, theta=sch.theta
            )

        if name == "subset":
            if pre is not None:
                k_srv, chor_pre = pre.k_srv, pre.chor_pre
            else:
                k_srv, k_q = jax.random.split(key)
                chor_pre = None
            if self._pick_servers is not None:
                servers = tuple(int(s) for s in self._pick_servers(sch.t))
            else:
                servers = tuple(
                    int(s) for s in subset.choose_servers(k_srv, sch.d, sch.t)
                )
            if len(servers) != sch.t:
                raise ValueError(
                    f"subset needs t={sch.t} servers, got {servers}"
                )
            packed = (
                chor.assemble_queries(chor_pre, q_idx) if chor_pre is not None
                else chor.gen_queries(k_q, n, sch.t, q_idx)
            )
            return RoutedBatch("mask", chor.query_masks(packed, n), servers, q_idx)

        if name in ("direct", "as-direct"):
            if pre is not None:
                raise ValueError("the direct family has no precompute half")
            reqs = direct.gen_queries(key, n, sch.d, sch.p, q_idx)
            return RoutedBatch("index", reqs, tuple(range(sch.d)), q_idx)

        raise ValueError(name)

    # -------------------------------------------------------- reconstruction
    def finalize(
        self, routed: RoutedBatch, responses: jnp.ndarray
    ) -> jnp.ndarray:
        """Per-server responses -> [B, W] packed records.

        mask kind : responses [d_eff, B, W] packed partial folds -> XOR.
        index kind: responses [d, B, p/d, W] gathered records -> select the
        slot holding the real query.
        """
        if routed.kind == "mask":
            return chor.reconstruct(responses)
        return direct.select_response(routed.payload, responses, routed.q_idx)
