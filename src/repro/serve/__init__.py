from repro.serve.engine import PIRServingEngine, ServerStats

__all__ = ["PIRServingEngine", "ServerStats"]
