"""repro.serve — the batch-scheduled, sharded PIR serving subsystem.

queue → router → backend: ``BatchScheduler`` decides when/how big batches
are, ``SchemeRouter`` turns a batch into per-server payloads for the
configured scheme, ``ShardedBackend`` answers them (single-host kernels
off-mesh; record-sharded Pallas + GF(2) collectives under an active
``repro.dist`` mesh). ``ServingPipeline`` composes the three and enforces
per-client (ε, δ) budgets; ``PIRServingEngine`` is the back-compat facade.
"""

from repro.serve.engine import PIRServingEngine, ServingPipeline
from repro.serve.router import RoutedBatch, SchemeRouter
from repro.serve.scheduler import BatchScheduler, Request, bucket_size
from repro.serve.sharded import ServerStats, ShardedBackend

__all__ = [
    "BatchScheduler",
    "PIRServingEngine",
    "Request",
    "RoutedBatch",
    "SchemeRouter",
    "ServerStats",
    "ServingPipeline",
    "ShardedBackend",
    "bucket_size",
]
