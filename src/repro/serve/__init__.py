"""repro.serve — the batch-scheduled, sharded PIR serving subsystem.

queue → router → backend: ``BatchScheduler`` decides when/how big batches
are, ``SchemeRouter`` drives the configured scheme's staged protocol
(DESIGN.md §Scheme protocol) to turn a batch into per-server payloads,
``ShardedBackend`` runs the answer stage (single-host kernels off-mesh;
record-sharded Pallas + GF(2) collectives under an active ``repro.dist``
mesh). ``ServingPipeline`` composes the three and enforces per-client
(ε, δ) budgets; ``PIRServingEngine`` is the back-compat facade. Both
accept staged scheme objects (incl. ``Anonymized`` wrappers) or the
legacy ``Scheme`` facade.

In front of and across the pipeline: ``AsyncFrontend`` is the thread-
backed (asyncio-compatible) concurrent ingest stage with per-request
futures, backpressure and graceful drain (DESIGN.md §Async front), and
``QueryCache`` the budget-aware cross-batch cache — per-(client, index)
answer memoization plus single-use precomputed batch randomness, every
hit still priced through the privacy budget (DESIGN.md §Cross-batch
cache).
"""

from repro.serve.cache import CacheEntry, QueryCache, scheme_signature
from repro.serve.engine import PIRServingEngine, PlannedBatch, ServingPipeline
from repro.serve.frontend import AsyncFrontend, BackpressureError
from repro.serve.router import RoutedBatch, SchemeRouter, SubsetPre
from repro.serve.scheduler import BatchScheduler, Request, bucket_size
from repro.serve.sharded import ServerStats, ShardedBackend

__all__ = [
    "AsyncFrontend",
    "BackpressureError",
    "BatchScheduler",
    "CacheEntry",
    "PIRServingEngine",
    "PlannedBatch",
    "QueryCache",
    "Request",
    "RoutedBatch",
    "SchemeRouter",
    "ServerStats",
    "ServingPipeline",
    "ShardedBackend",
    "SubsetPre",
    "bucket_size",
    "scheme_signature",
]
