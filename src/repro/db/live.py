"""Versioned record stores: MVCC deltas over the packed PIR substrate.

Every scheme in the repo answers against a frozen :class:`RecordStore`;
production databases churn (the Unified Framework paper frames PIR as
retrieving *up-to-date* information). This module is the seam between
those two facts: a :class:`VersionedStore` layers append/update/delete
:class:`Delta`\\ s over a base store, hands out **frozen snapshots** —
``snapshot(v)`` is bit-identical to a store rebuilt from scratch at
version ``v``, by construction and by test — and tells the serving stack
exactly which records each delta touched so invalidation can stay
incremental (DESIGN.md §13).

Consistency model (MVCC, single writer):

* Every :meth:`VersionedStore.ingest` produces a new immutable head
  ``RecordStore``; version numbers are the delta-log length. Snapshots
  are values: a reader holding one can never observe a later write
  (jnp buffers are immutable and ``RecordStore`` is frozen), so batch
  pinning in the serve layer is just "hold the snapshot object".
* ``update`` rewrites records in place (same ``n``); ``delete`` is a
  tombstone (the record zeroes, ``n`` stays) — record *indices are the
  address space clients query by*, so compaction would break every
  outstanding query; ``append`` grows ``n`` at the tail.
* Records partition into ``shards`` logical interleaved groups
  (``shard_of(i) = i % shards``, stable under append); ``shard_versions``
  records the last version that touched each shard, which is what the
  planner's incremental invalidation keys on.

The write path runs on device: update/delete deltas apply through
:func:`repro.kernels.backend.scatter_update` (the Pallas
scatter-into-packed-words kernel raced against the jnp oracle through
the backend registry), appends through a device concat. The host-numpy
replay in :func:`rebuild` is the independent oracle the device path is
asserted bit-identical against (tests/test_db_live.py).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.db import packing
from repro.db.store import RecordStore

__all__ = ["Delta", "VersionedStore", "apply_delta_np", "rebuild"]

# update/delete deltas larger than this apply in chunks so the scatter
# kernel's VMEM-resident payload stays bounded
_SCATTER_CHUNK = 4096


@dataclasses.dataclass(frozen=True)
class Delta:
    """One batch of writes against a specific store version.

    ``kind`` ∈ {"append", "update", "delete"}; ``indices`` are the target
    records for update/delete (**deduplicated, last write wins** — the
    constructors enforce it so every backend impl agrees on the result);
    ``raw`` is the [m, nbytes] uint8 payload for append/update.
    Construct via :meth:`append` / :meth:`update` / :meth:`delete`.
    """

    kind: str
    indices: Optional[np.ndarray] = None
    raw: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.kind not in ("append", "update", "delete"):
            raise ValueError(f"unknown delta kind {self.kind!r}")
        if self.kind != "delete" and (
            self.raw is None or self.raw.ndim != 2
        ):
            raise ValueError(f"{self.kind} delta needs a [m, nbytes] payload")
        if self.kind != "append" and self.indices is None:
            raise ValueError(f"{self.kind} delta needs target indices")

    @property
    def count(self) -> int:
        """How many records this delta writes."""
        if self.kind == "delete":
            return int(self.indices.shape[0])
        return int(self.raw.shape[0])

    # ------------------------------------------------------- constructors
    @classmethod
    def append(cls, raw: np.ndarray) -> "Delta":
        """New records at the tail: raw [m, nbytes] uint8."""
        return cls(kind="append", raw=np.ascontiguousarray(raw, np.uint8))

    @classmethod
    def update(cls, indices, raw) -> "Delta":
        """Rewrite existing records; duplicate targets keep the last
        payload (numpy assignment semantics — what both scatter impls
        and the replay oracle implement)."""
        idx = np.asarray(indices, np.int64).ravel()
        raw = np.ascontiguousarray(raw, np.uint8)
        if raw.shape[0] != idx.shape[0]:
            raise ValueError("update payload rows != index count")
        if idx.shape[0]:
            # last occurrence wins: unique over the reversed view finds
            # each target's final write
            _, first_rev = np.unique(idx[::-1], return_index=True)
            keep = np.sort(idx.shape[0] - 1 - first_rev)
            idx, raw = idx[keep], raw[keep]
        return cls(kind="update", indices=idx, raw=raw)

    @classmethod
    def delete(cls, indices) -> "Delta":
        """Tombstone records (zeroed, ``n`` unchanged — indices are the
        client-visible address space)."""
        idx = np.unique(np.asarray(indices, np.int64).ravel())
        return cls(kind="delete", indices=idx)


def _packed_rows(delta: Delta, store: RecordStore) -> np.ndarray:
    """The delta's payload, packed to the store's [m, W] word layout
    (zeros for a tombstone)."""
    nbytes = -(-store.record_bits // 8)
    if delta.kind == "delete":
        return np.zeros((delta.count, store.words), dtype=np.uint32)
    if delta.raw.shape[1] != nbytes:
        raise ValueError(
            f"delta payload is {delta.raw.shape[1]} bytes/record; "
            f"store records are {nbytes}"
        )
    return packing.pack_bytes_np(delta.raw)


def _check_targets(delta: Delta, n: int) -> None:
    if delta.kind == "append" or delta.count == 0:
        return
    lo, hi = int(delta.indices.min()), int(delta.indices.max())
    if lo < 0 or hi >= n:
        raise IndexError(
            f"{delta.kind} targets [{lo}, {hi}] out of range for n={n}"
        )


def apply_delta_np(
    packed: np.ndarray, record_bits: int, delta: Delta
) -> np.ndarray:
    """Host-numpy replay of one delta — the independent oracle the
    on-device ingest path is asserted bit-identical against."""
    store = RecordStore(packed=packed, record_bits=record_bits)  # view
    _check_targets(delta, packed.shape[0])
    rows = _packed_rows(delta, store)
    if delta.kind == "append":
        return np.concatenate([packed, rows], axis=0)
    out = np.array(packed, copy=True)
    out[delta.indices] = rows
    return out


def rebuild(base: RecordStore, deltas: Sequence[Delta]) -> RecordStore:
    """A store built from scratch: base + the delta log, replayed on the
    host. ``VersionedStore.snapshot(v)`` must be bit-identical to
    ``rebuild(base, log[:v])`` — the MVCC contract."""
    packed = np.asarray(base.packed)
    bits = base.record_bits
    for d in deltas:
        packed = apply_delta_np(packed, bits, d)
    return RecordStore(packed=jnp.asarray(packed), record_bits=bits)


class VersionedStore:
    """Append/update/delete deltas over a frozen base store, with
    versioned snapshots and shard-level touch tracking.

    ``shards`` controls the granularity the serving stack invalidates
    at; ``retain`` how many recent heads stay materialized (any version
    is still reachable — older snapshots rebuild from the delta log via
    the host oracle; in-flight serve batches pin their snapshot by
    holding the object, so retention only affects by-number access).
    ``backend`` picks the write-kernel registry entry
    (pallas / ref / auto) for delta application.

    **Compaction** (:meth:`compact`) rebases the store onto the current
    head: the head becomes the new frozen base, the delta log empties,
    and replay cost on :meth:`snapshot` resets to zero. Versions older
    than the new base become unreachable *by number* — in-flight readers
    that pinned a snapshot object are unaffected (the buffers are
    immutable), which is exactly the serve layer's pinning contract.
    ``shard_versions`` are absolute version numbers and survive the
    rebase untouched, so distributed invalidation keyed on
    :meth:`shards_touched_since` keeps working across a compaction.
    """

    def __init__(
        self,
        base: RecordStore,
        *,
        shards: int = 8,
        retain: int = 4,
        backend: str = "auto",
    ):
        if shards < 1:
            raise ValueError("shards must be >= 1")
        self.base = base
        self.shards = int(shards)
        self.backend = backend
        self._retain = max(1, int(retain))
        self._log: List[Delta] = []
        self._version = 0
        # compaction rebases `base` onto a later head; log entry i then
        # corresponds to version `_base_version + i + 1`
        self._base_version = 0
        self._heads: Dict[int, RecordStore] = {0: base}
        self._head = base
        #: per-shard last-touched version (the invalidation key)
        self.shard_versions: List[int] = [0] * self.shards
        self._lock = threading.Lock()
        self.metrics: Dict[str, int] = {
            "ingests": 0,
            "rows_appended": 0,
            "rows_updated": 0,
            "rows_deleted": 0,
            "snapshot_rebuilds": 0,
            "deltas_replayed": 0,
            "compactions": 0,
            "compacted_deltas": 0,
        }

    # ---------------------------------------------------------- accessors
    @property
    def version(self) -> int:
        return self._version

    @property
    def base_version(self) -> int:
        """The version the frozen base sits at (0 until a compaction)."""
        return self._base_version

    @property
    def log_depth(self) -> int:
        """Deltas currently in the log — the replay cost an evicted
        ``snapshot(v)`` can pay, and what :meth:`compact` resets."""
        return len(self._log)

    @property
    def n(self) -> int:
        return self._head.n

    @property
    def words(self) -> int:
        return self._head.words

    @property
    def record_bits(self) -> int:
        return self._head.record_bits

    def shard_of(self, index: int) -> int:
        """Stable shard mapping (interleaved groups: survives append)."""
        return int(index) % self.shards

    def shards_touched_since(self, version: int) -> Tuple[int, ...]:
        """Shards some delta after ``version`` touched — what must
        re-run precompute/re-plan; everything else keeps its state."""
        return tuple(
            s for s in range(self.shards) if self.shard_versions[s] > version
        )

    def touched_rows(self, delta: Delta, *, n_before: int) -> np.ndarray:
        """The record indices a delta writes (appends: the new tail)."""
        if delta.kind == "append":
            return np.arange(n_before, n_before + delta.count, dtype=np.int64)
        return np.asarray(delta.indices, np.int64)

    # ------------------------------------------------------------- writes
    def ingest(self, delta: Delta) -> int:
        """Apply one delta on device; returns the new version number.

        Single writer: concurrent ingests serialize on the store lock.
        The new head is a fresh frozen ``RecordStore``; earlier
        snapshots are untouched values.
        """
        with self._lock:
            head = self._head
            _check_targets(delta, head.n)
            rows_np = _packed_rows(delta, head)
            if delta.kind == "append":
                packed = jnp.concatenate(
                    [head.packed, jnp.asarray(rows_np)], axis=0
                )
                self.metrics["rows_appended"] += delta.count
            else:
                # lazy: db -> kernels is a layering inversion at import
                # time (kernels.backend imports repro.db); at call time
                # the registry is just the write-kernel chooser
                from repro.kernels.backend import scatter_update

                packed = head.packed
                idx = np.asarray(delta.indices, np.int64)
                for lo in range(0, idx.shape[0], _SCATTER_CHUNK):
                    sl = slice(lo, lo + _SCATTER_CHUNK)
                    packed = scatter_update(
                        packed, idx[sl], rows_np[sl], backend=self.backend
                    )
                key = (
                    "rows_updated" if delta.kind == "update"
                    else "rows_deleted"
                )
                self.metrics[key] += delta.count
            touched = self.touched_rows(delta, n_before=head.n)
            self._head = RecordStore(
                packed=packed, record_bits=head.record_bits
            )
            self._version += 1
            self._log.append(delta)
            self._heads[self._version] = self._head
            for s in np.unique(touched % self.shards):
                self.shard_versions[int(s)] = self._version
            self.metrics["ingests"] += 1
            # retention: keep the base and the last `retain` heads
            for v in [
                v for v in self._heads
                if v != self._base_version
                and v <= self._version - self._retain
            ]:
                del self._heads[v]
            return self._version

    # ------------------------------------------------------------ readers
    def snapshot(self, version: Optional[int] = None) -> RecordStore:
        """The immutable store at ``version`` (default: head).

        Bit-identical to :func:`rebuild`\\ (base, log[:version]) — from a
        retained head for recent versions, by host replay for evicted
        ones (counted in ``metrics["snapshot_rebuilds"]``; replay seeds
        from the *nearest* retained head at or below ``version``, never
        the full log, and ``metrics["deltas_replayed"]`` counts exactly
        how many deltas that replay applied). Versions older than the
        compaction base are unreachable by number (readers that pinned
        the snapshot object still hold it)."""
        with self._lock:
            if version is None or version == self._version:
                return self._head
            if version < 0 or version > self._version:
                raise ValueError(
                    f"version {version} out of range [0, {self._version}]"
                )
            if version < self._base_version:
                raise ValueError(
                    f"version {version} predates the compaction base "
                    f"{self._base_version} (log rebased away)"
                )
            hit = self._heads.get(version)
            if hit is not None:
                return hit
            # seed from the nearest retained head below `version` (the
            # base-version head is always retained, so max() is safe)
            seed_v = max(v for v in self._heads if v < version)
            seed = self._heads[seed_v]
            log = list(
                self._log[seed_v - self._base_version:
                          version - self._base_version]
            )
        self.metrics["snapshot_rebuilds"] += 1
        self.metrics["deltas_replayed"] += len(log)
        return rebuild(seed, log)

    # --------------------------------------------------------- compaction
    def compact(self, *, check: bool = True) -> int:
        """Rebase onto the current head: head becomes the new frozen
        base, the delta log empties. Returns how many deltas were
        compacted away (0 when the log is already empty or a concurrent
        ingest raced the oracle check — callers retry on the next idle
        tick).

        ``check=True`` (the default, and what the serve layer's
        idle-slot compaction uses) replays the log through the host
        oracle and asserts the result bit-identical to the head before
        installing it — a compaction can never silently corrupt the
        base. The oracle replay runs *outside* the store lock so writes
        never block on it.
        """
        with self._lock:
            if not self._log:
                return 0
            base, log = self.base, list(self._log)
            head, ver = self._head, self._version
        if check:
            oracle = rebuild(base, log)
            if oracle.record_bits != head.record_bits or not np.array_equal(
                np.asarray(oracle.packed), np.asarray(head.packed)
            ):
                raise RuntimeError(
                    "compaction oracle mismatch: rebuild(base, log) is "
                    "not bit-identical to the head — refusing to rebase"
                )
        with self._lock:
            if self._version != ver:
                return 0  # a write landed mid-check; retry next idle slot
            self.base = head
            self._base_version = ver
            self._log = []
            self._heads = {
                v: h for v, h in self._heads.items() if v >= ver
            }
            self._heads[ver] = head
            self.metrics["compactions"] += 1
            self.metrics["compacted_deltas"] += len(log)
            return len(log)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"VersionedStore(v={self._version}, n={self.n}, "
            f"shards={self.shards})"
        )
