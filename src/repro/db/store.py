"""Record store: the replicated PIR database substrate.

A :class:`RecordStore` holds ``n`` records of a standard size ``record_bits``
(paper §2.1: records of standardized size b bits), bit-packed into uint32
words. The store is what every scheme's *server side* operates on.

Sharding: on a production mesh the record axis (``n``) is sharded over the
``model`` axis and, optionally, the word axis over nothing (records are small
relative to n). ``shard_spec()`` produces the PartitionSpec used by the
launch layer; the store itself is mesh-agnostic so unit tests run on one CPU
device.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.db import packing

__all__ = ["RecordStore", "make_synthetic_store"]


@dataclasses.dataclass(frozen=True, eq=False)
class RecordStore:
    """``packed``: [n, W] uint32; ``record_bits``: true record width in bits.

    Frozen: a store is an immutable value. Mutation happens one layer up —
    :class:`repro.db.live.VersionedStore` layers append/update/delete deltas
    over a base store and hands out frozen snapshots, which may safely share
    the packed buffer because nothing can write through this class (jnp
    arrays are immutable and the dataclass rejects attribute assignment).
    """

    packed: jnp.ndarray
    record_bits: int

    # ---------------------------------------------------------------- basics
    @property
    def n(self) -> int:
        return self.packed.shape[0]

    @property
    def words(self) -> int:
        return self.packed.shape[1]

    @property
    def nbytes(self) -> int:
        return self.packed.size * 4

    # ------------------------------------------------------------ construct
    @classmethod
    def from_bytes(cls, raw: np.ndarray) -> "RecordStore":
        """[n, nbytes] uint8 host array -> store."""
        packed = packing.pack_bytes_np(np.asarray(raw, dtype=np.uint8))
        return cls(packed=jnp.asarray(packed), record_bits=raw.shape[1] * 8)

    @classmethod
    def from_float_table(cls, table: jnp.ndarray) -> "RecordStore":
        """[n, dim] float32 table -> store (bit-exact transport via bitcast)."""
        u32 = packing.bitcast_f32_to_u32(table)
        return cls(packed=u32, record_bits=table.shape[1] * 32)

    # -------------------------------------------------------------- readout
    def record_bytes(self, i: int) -> np.ndarray:
        nbytes = -(-self.record_bits // 8)
        row = np.asarray(self.packed[i : i + 1])
        return packing.unpack_bytes_np(row, nbytes)[0]

    def as_float_table(self) -> jnp.ndarray:
        if self.record_bits % 32:
            raise ValueError("store was not built from a float table")
        return packing.bitcast_u32_to_f32(self.packed)

    def bitplanes(self, dtype=jnp.float32) -> jnp.ndarray:
        """[n, 32*W] {0,1} planes for the parity-matmul (MXU) server path."""
        return packing.bitplanes_from_packed(self.packed, dtype=dtype)

    # ------------------------------------------------------------- sharding
    def shard_spec(self, record_axis: Optional[str] = "model"):
        """PartitionSpec sharding the record axis; words replicated."""
        from jax.sharding import PartitionSpec as P

        return P(record_axis, None)


def make_synthetic_store(
    n: int, record_bytes: int, seed: int = 0
) -> RecordStore:
    """Deterministic synthetic database (used by tests/benches/examples)."""
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 256, size=(n, record_bytes), dtype=np.uint8)
    return RecordStore.from_bytes(raw)
