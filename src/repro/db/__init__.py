from repro.db.live import Delta, VersionedStore, rebuild
from repro.db.packing import (
    WORD_BITS,
    bitcast_f32_to_u32,
    bitcast_u32_to_f32,
    pack_bits,
    unpack_bits,
    words_per_record,
)
from repro.db.store import RecordStore, make_synthetic_store

__all__ = [
    "WORD_BITS",
    "Delta",
    "RecordStore",
    "VersionedStore",
    "bitcast_f32_to_u32",
    "bitcast_u32_to_f32",
    "make_synthetic_store",
    "pack_bits",
    "rebuild",
    "unpack_bits",
    "words_per_record",
]
