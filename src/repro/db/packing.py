"""Bit packing for PIR record stores.

PIR over GF(2) operates on raw record bits. TPUs move data in 32-bit lanes,
so records are padded to a multiple of 32 bits and packed into uint32 words
("W words per record"). Two layouts are used by the kernels:

  * packed  : [n, W] uint32 — one row per record (XOR-fold / gather-XOR path)
  * bitplane: [n, B] uint8/{0,1} — one column per bit (parity-matmul path)

All functions are jnp-first and jit-safe; numpy twins (``*_np``) exist for
host-side store construction so a multi-GB database never has to round-trip
through a device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32

__all__ = [
    "WORD_BITS",
    "words_per_record",
    "pack_bits",
    "unpack_bits",
    "pack_bytes_np",
    "unpack_bytes_np",
    "bitcast_f32_to_u32",
    "bitcast_u32_to_f32",
    "bitplanes_from_packed",
    "packed_from_bitplanes",
]


def words_per_record(record_bits: int) -> int:
    """Number of uint32 words needed for a record of ``record_bits`` bits."""
    if record_bits <= 0:
        raise ValueError(f"record_bits must be positive, got {record_bits}")
    return -(-record_bits // WORD_BITS)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a [..., B] array of {0,1} into [..., ceil(B/32)] uint32 (LSB first)."""
    *lead, b = bits.shape
    w = words_per_record(b)
    pad = w * WORD_BITS - b
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*lead, pad), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(*lead, w, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, num_bits: int | None = None) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`: [..., W] uint32 -> [..., num_bits] uint8."""
    *lead, w = words.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*lead, w * WORD_BITS).astype(jnp.uint8)
    if num_bits is not None:
        bits = bits[..., :num_bits]
    return bits


def pack_bytes_np(raw: np.ndarray) -> np.ndarray:
    """Host-side: [n, nbytes] uint8 -> [n, W] uint32 (little-endian words)."""
    n, nbytes = raw.shape
    w = words_per_record(nbytes * 8)
    pad = w * 4 - nbytes
    if pad:
        raw = np.concatenate([raw, np.zeros((n, pad), dtype=np.uint8)], axis=1)
    return raw.reshape(n, w, 4).view(np.uint8).copy().view("<u4").reshape(n, w)


def unpack_bytes_np(words: np.ndarray, nbytes: int) -> np.ndarray:
    """Inverse of :func:`pack_bytes_np`."""
    n, w = words.shape
    raw = words.astype("<u4").view(np.uint8).reshape(n, w * 4)
    return raw[:, :nbytes].copy()


def bitcast_f32_to_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Reinterpret float32 as uint32 (exact bit transport through XOR-PIR)."""
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def bitcast_u32_to_f32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x, jnp.float32)


def bitplanes_from_packed(words: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """[n, W] uint32 -> [n, 32*W] {0,1} planes for the parity-matmul path."""
    return unpack_bits(words).astype(dtype)


def packed_from_bitplanes(planes: jnp.ndarray) -> jnp.ndarray:
    """[n, B] {0,1} (any numeric dtype) -> [n, ceil(B/32)] uint32."""
    return pack_bits(planes.astype(jnp.uint8))
