#!/usr/bin/env python
"""Doc-integrity checker (CI step): the docs layer must never dangle.

Three passes over the repo:

1. **Doc references from code** — every ``*.md`` path mentioned in a
   Python file under src/, tests/, benchmarks/, examples/ must exist
   (resolved against the repo root, then the referencing file's
   directory). Paths of *generated* artifacts are allowlisted.
2. **Section citations** — the adjacent-citation form
   ``FILE.md §Anchor`` (also chained: ``FILE.md §A/§B``) must resolve:
   the cited file must contain a heading whose text contains the
   anchor token. ``DESIGN.md §Hardware adaptation`` passes because
   DESIGN.md has ``## §3 · Hardware adaptation``.
3. **Markdown links** — every intra-repo ``[text](target)`` link in
   every ``*.md`` file must point at an existing file or directory
   (external http(s)/mailto links and pure #fragments are skipped).

Exit status 0 iff all passes are clean; failures are printed one per
line. Run: ``python tools/check_docs.py``.
"""

from __future__ import annotations

import pathlib
import re
import sys
from typing import Dict, List

ROOT = pathlib.Path(__file__).resolve().parent.parent

CODE_DIRS = ("src", "tests", "benchmarks", "examples")
SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache", "node_modules"}

# md paths that code writes rather than reads — absence is not a dangle
GENERATED_MD = {"results/roofline.md"}

MD_REF = re.compile(r"[\w][\w./-]*\.md\b")
# FILE.md §Tok [/ §Tok ...] — the citation form docstrings use
_TOK = r"[\w](?:[\w.-]*[\w])?"  # no trailing punctuation
SECTION_REF = re.compile(
    rf"([\w][\w./-]*\.md)\s*§({_TOK})((?:\s*/\s*§{_TOK})*)"
)
SECTION_TAIL = re.compile(rf"§({_TOK})")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def iter_files(suffix: str):
    roots = [ROOT / d for d in CODE_DIRS] if suffix == ".py" else [ROOT]
    for root in roots:
        if not root.is_dir():
            continue
        for path in sorted(root.rglob(f"*{suffix}")):
            if not SKIP_DIRS.intersection(p.name for p in path.parents):
                yield path


def resolve(ref: str, from_file: pathlib.Path) -> bool:
    ref = ref.rstrip("/")
    return (ROOT / ref).exists() or (from_file.parent / ref).exists()


def headings_of(md_rel: str, cache: Dict[str, List[str]]) -> List[str]:
    if md_rel not in cache:
        path = ROOT / md_rel
        cache[md_rel] = (
            HEADING.findall(path.read_text(encoding="utf-8"))
            if path.is_file() else []
        )
    return cache[md_rel]


def check_code_references() -> List[str]:
    errors = []
    heading_cache: Dict[str, List[str]] = {}
    for path in iter_files(".py"):
        text = path.read_text(encoding="utf-8")
        rel = path.relative_to(ROOT)
        for lineno, line in enumerate(text.splitlines(), 1):
            for ref in MD_REF.findall(line):
                if ref in GENERATED_MD or resolve(ref, path):
                    continue
                errors.append(f"{rel}:{lineno}: references missing doc {ref!r}")
            for m in SECTION_REF.finditer(line):
                md, first, tail = m.group(1), m.group(2), m.group(3)
                md_rel = md if (ROOT / md).is_file() else None
                if md_rel is None:
                    continue  # missing file already reported above
                heads = headings_of(md_rel, heading_cache)
                for tok in [first] + SECTION_TAIL.findall(tail):
                    if not any(tok.lower() in h.lower() for h in heads):
                        errors.append(
                            f"{rel}:{lineno}: cites {md} §{tok} but no "
                            f"heading of {md} contains {tok!r}"
                        )
    return errors


def check_markdown_links() -> List[str]:
    errors = []
    for path in iter_files(".md"):
        rel = path.relative_to(ROOT)
        for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1
        ):
            for target in MD_LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                target = target.split("#", 1)[0]
                if target and not resolve(target, path):
                    errors.append(f"{rel}:{lineno}: dead link ({target})")
    return errors


def main() -> int:
    errors = check_code_references() + check_markdown_links()
    for err in errors:
        print(err)
    print(
        f"check_docs: {'FAIL' if errors else 'ok'} "
        f"({len(errors)} dangling reference(s))"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
