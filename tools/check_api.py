#!/usr/bin/env python
"""API-boundary checker (CI step): the staged SchemeProtocol is the only
door to the per-scheme wire internals, and the execution-backend layer
is the only door to the kernel internals.

Seven passes:

1. **Protocol boundary** — no library module outside ``repro.core``
   (i.e. under src/repro but not src/repro/core), and no benchmark or
   example, may import the per-scheme wire modules
   (``repro.core.chor`` / ``sparse`` / ``direct`` / ``subset``). Those
   are implementation details behind the registry (DESIGN.md §Scheme
   protocol); consumers go through ``repro.core.protocol``
   (``build_scheme`` / ``Anonymized`` / the scheme classes) or the
   back-compat ``Scheme`` facade. tests/ are exempt — the conformance
   and wire-level unit suites deliberately pin the internals.
2. **Kernel boundary** — same rule for the kernel internals behind the
   execution-backend layer (DESIGN.md §Execution backends): no module
   outside ``repro.kernels`` may import the raw kernel modules
   (``repro.kernels.gather_xor`` / ``xor_fold`` / ``parity_matmul`` /
   ``fused`` / ``scatter``) or pull ``gather_xor``/``xor_fold``/
   ``parity_matmul``/``fused_gather_fold``/``fused_multi_gather_fold``/
   ``scatter_rows`` from the package.
   Kernel choice flows through
   ``repro.kernels.backend`` (ExecutionPlan/KernelPlanner) or the
   ``repro.kernels.ops`` wrappers; the ``ref`` oracles and
   ``indices_from_mask`` stay public (they are the correctness ground
   truth and the mask→index utility, not kernel choices).
3. **Fleet layering** — the fleet harness (``repro.fleet``, DESIGN.md
   §Fleet harness) sits *above* the serving stack: it may import
   ``repro.serve`` and ``repro.dist``, but never anything under
   ``repro.kernels`` (any submodule or the package itself) nor the
   per-scheme wire internals. Load generation drives the public
   pipeline; if the harness needs a kernel- or wire-level knob, that
   knob belongs on the pipeline's API, not in the harness.
4. **Live-store boundary** — the serving layer consumes *snapshots*;
   it never mutates a store directly (DESIGN.md §13). Within
   ``repro.serve`` only ``engine.py`` — the one module that owns the
   ingest path — may import ``repro.db.live`` or pull
   ``Delta``/``VersionedStore``/``rebuild`` from ``repro.db``. Every
   other serve module (scheduler, cache, frontend, sharded) sees
   frozen ``RecordStore`` snapshots only, so snapshot consistency is
   structural: nothing outside the engine can even name a writer.
5. **Snapshot immutability** — no module outside ``repro.db`` may
   *assign* to a store's ``.packed`` / ``.record_bits`` attributes
   (``x.packed = ...``, augmented or chained included). Pinning a
   snapshot is just holding the object (engine docstring); that only
   works if nobody pokes its fields. tests/ are exempt as usual.
6. **__all__ consistency** — every ``repro.*`` module that declares
   ``__all__`` must actually define each listed name, with no
   duplicates.
7. **Shard-version boundary** — the live store's shard-version
   internals (``shard_versions`` / ``shards_touched_since``, the
   distributed-invalidation key, DESIGN.md §13) are read only by
   ``repro.db`` itself and the sharded serve backend
   (``repro/serve/sharded.py``). Everything else gets the aggregated
   swap counters; code that keys on raw shard versions outside those
   two places would fork the invalidation protocol. tests/ are exempt
   as usual.

Exit status 0 iff all passes are clean; failures print one per line.
Run: ``python tools/check_api.py``.
"""

from __future__ import annotations

import ast
import importlib
import pathlib
import sys
from typing import List, Set

ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC = ROOT / "src"
sys.path.insert(0, str(SRC))

# the per-scheme wire modules fenced behind the protocol registry
INTERNAL = {"chor", "sparse", "direct", "subset"}
INTERNAL_MODULES = {f"repro.core.{m}" for m in INTERNAL}

# the raw kernel modules fenced behind the execution-backend layer
KERNEL_INTERNAL = {"gather_xor", "xor_fold", "parity_matmul", "fused",
                   "scatter"}
KERNEL_INTERNAL_MODULES = {f"repro.kernels.{m}" for m in KERNEL_INTERNAL}
# names that must not be pulled from the repro.kernels package either:
# the kernel functions AND the submodules themselves (`from repro.kernels
# import fused` is the same breach as `import repro.kernels.fused`)
KERNEL_INTERNAL_NAMES = KERNEL_INTERNAL | {
    "fused_gather_fold", "fused_multi_gather_fold", "scatter_rows"
}

# the writer types fenced behind the engine's ingest path: every serve
# module except engine.py sees frozen snapshots only
LIVE_INTERNAL_MODULES = {"repro.db.live"}
LIVE_INTERNAL_NAMES = {"live", "Delta", "VersionedStore", "rebuild"}

# store fields nobody outside repro.db may assign to (snapshot pinning
# relies on the packed words being frozen)
STORE_FROZEN_ATTRS = {"packed", "record_bits"}

# the live store's shard-version internals: the distributed-invalidation
# key, readable only by repro.db and the sharded serve backend
SHARD_VERSION_INTERNALS = {"shard_versions", "shards_touched_since"}

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache"}


def iter_py(root: pathlib.Path):
    for path in sorted(root.rglob("*.py")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def _violations_in(
    tree: ast.AST,
    package: str,
    internal_modules: Set[str],
    parent_pkg: str,
    internal_names: Set[str],
) -> List[str]:
    """Names of fenced modules a parsed file imports.

    ``package`` is the file's own package (e.g. "repro.serve"), used to
    resolve relative imports — ``from ..core import chor`` inside
    repro.serve is the same breach as the absolute spelling.
    ``internal_names`` are names that count as a breach when pulled
    straight from ``parent_pkg`` (``from repro.kernels import
    xor_fold``)."""
    bad = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in internal_modules or any(
                    alias.name.startswith(m + ".") for m in internal_modules
                ):
                    bad.append(alias.name)
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:  # relative: resolve against the file's package
                parts = package.split(".") if package else []
                if node.level - 1 > len(parts):
                    continue  # would not import at runtime either
                base = parts[: len(parts) - (node.level - 1)]
                mod = ".".join(base + ([mod] if mod else []))
            if mod in internal_modules or any(
                mod.startswith(m + ".") for m in internal_modules
            ):
                bad.append(mod)
            elif mod == parent_pkg:
                bad.extend(
                    f"{parent_pkg}.{a.name}"
                    for a in node.names
                    if a.name in internal_names
                )
    return bad


def _check_fence(
    fence_exempt: pathlib.Path,
    internal_modules: Set[str],
    parent_pkg: str,
    internal_names: Set[str],
    hint: str,
) -> List[str]:
    errors = []
    scopes = [SRC / "repro", ROOT / "benchmarks", ROOT / "examples"]
    for scope in scopes:
        if not scope.is_dir():
            continue
        for path in iter_py(scope):
            if fence_exempt in path.parents:
                continue  # the fenced package owns its internals
            tree = ast.parse(path.read_text(encoding="utf-8"))
            rel = path.relative_to(ROOT)
            if scope == SRC / "repro":
                # a plain module's package drops the module name; for an
                # __init__.py dropping "__init__" leaves the package
                # itself — both are parts[:-1]
                parts = list(path.relative_to(SRC).with_suffix("").parts)
                package = ".".join(parts[:-1])
            else:  # benchmarks/examples are not packages
                package = ""
            for mod in _violations_in(
                tree, package, internal_modules, parent_pkg, internal_names
            ):
                errors.append(f"{rel}: imports internal {mod!r} — {hint}")
    return errors


def check_protocol_boundary() -> List[str]:
    return _check_fence(
        SRC / "repro" / "core",
        INTERNAL_MODULES,
        "repro.core",
        INTERNAL,
        "use repro.core.protocol (registry/Anonymized) or the Scheme "
        "facade instead",
    )


def check_kernel_boundary() -> List[str]:
    return _check_fence(
        SRC / "repro" / "kernels",
        KERNEL_INTERNAL_MODULES,
        "repro.kernels",
        KERNEL_INTERNAL_NAMES,
        "kernel choice flows through repro.kernels.backend "
        "(ExecutionPlan/KernelPlanner) or repro.kernels.ops",
    )


# everything the fleet harness may never import: the whole kernel layer
# (any submodule — kernel choice is the pipeline's concern) plus the
# per-scheme wire internals
FLEET_BANNED_MODULES = {"repro.kernels"} | INTERNAL_MODULES


def check_fleet_boundary() -> List[str]:
    errors = []
    scope = SRC / "repro" / "fleet"
    if not scope.is_dir():
        return errors
    for path in iter_py(scope):
        tree = ast.parse(path.read_text(encoding="utf-8"))
        parts = list(path.relative_to(SRC).with_suffix("").parts)
        package = ".".join(parts[:-1])
        for mod in _violations_in(
            tree, package, FLEET_BANNED_MODULES, "repro.core", INTERNAL
        ):
            errors.append(
                f"{path.relative_to(ROOT)}: imports {mod!r} — the fleet "
                "harness drives the public serving pipeline (repro.serve / "
                "repro.dist); kernel and per-scheme wire internals are fenced"
            )
    return errors


def check_live_boundary() -> List[str]:
    """Serve consumes snapshots; only the engine may name the writer."""
    errors = []
    scope = SRC / "repro" / "serve"
    if not scope.is_dir():
        return errors
    for path in iter_py(scope):
        if path.name == "engine.py":
            continue  # the one ingest door (DESIGN.md §13)
        tree = ast.parse(path.read_text(encoding="utf-8"))
        parts = list(path.relative_to(SRC).with_suffix("").parts)
        package = ".".join(parts[:-1])
        for mod in _violations_in(
            tree, package, LIVE_INTERNAL_MODULES, "repro.db",
            LIVE_INTERNAL_NAMES,
        ):
            errors.append(
                f"{path.relative_to(ROOT)}: imports {mod!r} — serve "
                "consumes frozen snapshots; store mutation flows through "
                "ServingPipeline.ingest (repro.serve.engine) only"
            )
    return errors


def check_store_immutability() -> List[str]:
    """No assignment to a store's packed words outside repro.db."""
    errors = []
    db_pkg = SRC / "repro" / "db"
    scopes = [SRC / "repro", ROOT / "benchmarks", ROOT / "examples"]
    for scope in scopes:
        if not scope.is_dir():
            continue
        for path in iter_py(scope):
            if db_pkg in path.parents:
                continue  # the store owns its own fields
            tree = ast.parse(path.read_text(encoding="utf-8"))
            for node in ast.walk(tree):
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                for tgt in targets:
                    if (
                        isinstance(tgt, ast.Attribute)
                        and tgt.attr in STORE_FROZEN_ATTRS
                    ):
                        errors.append(
                            f"{path.relative_to(ROOT)}:{node.lineno}: "
                            f"assigns '.{tgt.attr}' — store words are "
                            "frozen outside repro.db; go through "
                            "VersionedStore deltas"
                        )
    return errors


def check_shard_version_boundary() -> List[str]:
    """Shard-version internals stay inside db/ + serve/sharded.py."""
    errors = []
    db_pkg = SRC / "repro" / "db"
    sharded = SRC / "repro" / "serve" / "sharded.py"
    scopes = [SRC / "repro", ROOT / "benchmarks", ROOT / "examples"]
    for scope in scopes:
        if not scope.is_dir():
            continue
        for path in iter_py(scope):
            if db_pkg in path.parents or path == sharded:
                continue
            tree = ast.parse(path.read_text(encoding="utf-8"))
            rel = path.relative_to(ROOT)
            for node in ast.walk(tree):
                names = []
                if (
                    isinstance(node, ast.Attribute)
                    and node.attr in SHARD_VERSION_INTERNALS
                ):
                    names = [node.attr]
                elif isinstance(node, ast.ImportFrom) and (
                    node.module or ""
                ).startswith("repro.db"):
                    names = [
                        a.name for a in node.names
                        if a.name in SHARD_VERSION_INTERNALS
                    ]
                for name in names:
                    errors.append(
                        f"{rel}:{node.lineno}: reads {name!r} — the "
                        "shard-version vector is the db/serve.sharded "
                        "invalidation protocol; consume the swap_store "
                        "counters instead (DESIGN.md §13)"
                    )
    return errors


def check_all_consistency() -> List[str]:
    errors = []
    for path in iter_py(SRC / "repro"):
        rel = path.relative_to(SRC)
        parts = list(rel.with_suffix("").parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mod_name = ".".join(parts)
        try:
            module = importlib.import_module(mod_name)
        except Exception as exc:  # a broken module is an API failure too
            errors.append(f"{path.relative_to(ROOT)}: import failed ({exc})")
            continue
        declared = getattr(module, "__all__", None)
        if declared is None:
            continue
        if len(set(declared)) != len(declared):
            dupes = sorted(
                {n for n in declared if declared.count(n) > 1}
            )
            errors.append(
                f"{path.relative_to(ROOT)}: __all__ has duplicates {dupes}"
            )
        for name in declared:
            if not hasattr(module, name):
                errors.append(
                    f"{path.relative_to(ROOT)}: __all__ exports "
                    f"{name!r} but the module does not define it"
                )
    return errors


def main() -> int:
    errors = (
        check_protocol_boundary()
        + check_kernel_boundary()
        + check_fleet_boundary()
        + check_live_boundary()
        + check_store_immutability()
        + check_shard_version_boundary()
        + check_all_consistency()
    )
    for err in errors:
        print(err)
    print(
        f"check_api: {'FAIL' if errors else 'ok'} "
        f"({len(errors)} violation(s))"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
